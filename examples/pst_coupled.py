"""DAG-of-ensembles: cross-pipeline coupling through typed data-flow ports.

Three pipelines on ONE pilot session, coupled by Channels (core/flow.py):

  producer   an ensemble of simulators; every cycle's stage streams its
             member results into the "trajectories" channel
  analysis   a shared analysis ensemble; each round takes ONE trajectory
             put — round 0 starts while the producer is still on cycle 1
  feedback   consumes the analysis "weights" stream and re-weights the
             sampling (here: prints the decision)

This is coupling the 2016 hook API could not express: the analysis
pipeline belongs to no producer cycle and the feedback stage couples to
the analysis output only — a true DAG of ensembles, with every edge
resolved into task dependencies on the shared session (no global barrier,
no teardown between cycles).

    PYTHONPATH=src python examples/pst_coupled.py --sim   # DES, instant
    PYTHONPATH=src python examples/pst_coupled.py         # real kernels
    PYTHONPATH=src python examples/pst_coupled.py --validate-only
                                                   # pre-flight lint only

Set REPRO_JOURNAL_DIR to journal the run (the CI sanitizer gate replays
the journal's invariants with ``python -m repro.analysis sanitize``).
"""
import argparse
import sys

from repro.core import AppManager, Channel, Kernel, PipelineSpec, Stage, \
    TaskSpec
from repro.runtime.executor import PilotRuntime
from repro.runtime.journal import journal_from_env

CYCLES = 3
MEMBERS = 4


def kernel(mode, sim_duration, payload=None):
    if mode == "sim":
        k = Kernel("synthetic.noop")
        k.sim_duration = sim_duration
    else:
        k = Kernel("synthetic.echo")
        k.arguments = {"value": payload}
    return k


def build(mode):
    traj = Channel("trajectories")
    weights = Channel("weights")

    producer = PipelineSpec(
        [Stage([TaskSpec(kernel(mode, 4.0, {"member": m, "cycle": c}),
                         name=f"prod.c{c}.md{m}")
                for m in range(MEMBERS)],
               name=f"cycle{c}", outputs=[traj])
         for c in range(CYCLES)], name="producer")

    analysis = PipelineSpec(
        [Stage([TaskSpec(kernel(mode, 1.0, {"round": c}),
                         name=f"ana.r{c}")],
               name=f"round{c}", inputs={"traj": traj}, outputs=[weights])
         for c in range(CYCLES)], name="analysis")

    feedback = PipelineSpec(
        [Stage([TaskSpec(kernel(mode, 0.5, {"fb": c}),
                         name=f"fb.r{c}")],
               name=f"fb{c}", inputs={"weights": weights})
         for c in range(CYCLES)], name="feedback")

    return [producer, analysis, feedback]


def validate_only(mode) -> int:
    """Pre-flight lint of the declared pipelines; no task launches."""
    from repro.analysis import validate_app
    report = validate_app(build(mode))
    print(report.format())
    return 0 if report.ok else 1


def main(mode, trace_out=None):
    # journal name carries the mode: a sim journal must not be replayed
    # into a real run (same task names would be skipped as already done)
    tracer = None
    if trace_out:
        from repro.obs import Tracer
        tracer = Tracer()
    rt = PilotRuntime(slots=MEMBERS + 2, mode=mode,
                      journal=journal_from_env(f"pst_coupled_{mode}"),
                      tracer=tracer)
    am = AppManager(rt)
    prof = am.run(build(mode), validate="error")

    pipes = prof.results["pipelines"]
    print(f"mode={mode}: ttc={prof.ttc:.2f}s, {prof.n_tasks} tasks, "
          f"utilization={prof.utilization:.2f}")
    for name, info in pipes.items():
        print(f"  {name}: {info['state']} after {info['n_tasks']} tasks")
    assert all(info["state"] == "done" for info in pipes.values())
    assert prof.n_failed == 0

    ch = am.channels
    print(f"  channels: {ch['trajectories']!r}, {ch['weights']!r}")

    if mode == "sim":
        g = am.session.graph
        ana0_start = g.tasks["ana.r0"].v_started
        producer_drained = max(g.tasks[f"prod.c{CYCLES - 1}.md{m}"].v_finished
                               for m in range(MEMBERS))
        print(f"  analysis round 0 started at v={ana0_start:.1f}s; producer "
              f"drained at v={producer_drained:.1f}s")
        # the acceptance property: a consumer stage in pipeline B runs
        # BEFORE its producer pipeline A has fully drained
        assert ana0_start < producer_drained, \
            "analysis must start inside the producer's run"
        fb0_start = g.tasks["fb.r0"].v_started
        assert fb0_start < producer_drained
        print("  consumer stages streamed inside the producer's lifetime: "
              "cross-pipeline DAG confirmed")

    if trace_out:
        from repro.obs import to_chrome
        from repro.obs.tracer import TASK
        ts = prof.results["timeseries"]
        assert ts["n_samples"] > 0, "tracer sampled no metrics ticks"
        assert not [s for s in tracer.unpaired() if s["cat"] == TASK], \
            "unpaired task spans at drain end"
        with open(trace_out, "w") as f:
            f.write(to_chrome(_live_segments(rt)))
        print(f"  trace: {len(tracer.spans)} spans, "
              f"{ts['n_samples']} metric samples -> {trace_out}")


def _live_segments(rt):
    """Chrome-export source: this run's own journal when it was captured
    (REPRO_JOURNAL_DIR), else the live tracer's spans."""
    from repro.obs import load_segments
    from repro.obs.report import segment_from_tracer
    path = rt.journal.path
    if path:
        return [(f"pst_coupled#{s.index}", s) for s in load_segments(path)]
    return [("pst_coupled", segment_from_tracer(rt.tracer))]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--sim", action="store_true",
                    help="DES mode: modeled durations, instant wall clock")
    ap.add_argument("--validate-only", action="store_true",
                    help="lint the declared pipelines and exit (no run)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="attach a flight recorder (repro.obs.Tracer) and "
                         "write a Chrome/Perfetto trace here")
    args = ap.parse_args()
    mode = "sim" if args.sim else "real"
    if args.validate_only:
        sys.exit(validate_only(mode))
    main(mode, trace_out=args.trace_out)
