"""Federated PST showcase: the SAME coupled ensemble app, run on one
pilot, then on a 2-pilot fleet, then on an elastic fleet that recruits
pilots against the backlog, then through a whole-pilot failure — without
changing a line of the application.  The only thing that varies is the
runtime object handed to AppManager.

    PYTHONPATH=src python examples/pst_federated.py [--fast]
    PYTHONPATH=src python examples/pst_federated.py --validate-only

Set REPRO_JOURNAL_DIR to capture per-pilot journals (federated runs write
one file per pilot plus a fleet file; the CI sanitizer gate replays every
file's invariants afterwards).
"""
import argparse
import os
import sys

from repro.core import AppManager, Channel, Kernel, PipelineSpec, Stage, \
    TaskSpec
from repro.federation import Recruiter, build_fleet

PILOT_SLOTS = 8
FULL = dict(pipelines=4, cycles=10, members=8)    # 320 members + 40 ana
FAST = dict(pipelines=4, cycles=4, members=4)     # 64 members + 16 ana
MEMBER_NBYTES = 64 << 20


def _member(dur=1.0, nbytes=MEMBER_NBYTES):
    k = Kernel("synthetic.noop")
    k.sim_duration = dur
    k.output_nbytes = nbytes
    return k


def _coupled(pipelines, cycles, members):
    """P producer ensembles streaming cycle outputs into channels consumed
    by P analysis pipelines (the staging bench's coupled shape)."""
    pipes = []
    for p in range(pipelines):
        ch = Channel(f"traj{p}")
        pipes.append(PipelineSpec(
            [Stage([TaskSpec(_member(), name=f"p{p}.c{c}.m{m}")
                    for m in range(members)],
                   name=f"cycle{c}", outputs=[ch])
             for c in range(cycles)], name=f"producer{p}"))
        pipes.append(PipelineSpec(
            [Stage([TaskSpec(_member(dur=0.5, nbytes=0),
                             name=f"a{p}.r{c}")],
                   name=f"round{c}", inputs={"traj": ch})
             for c in range(cycles)], name=f"analysis{p}"))
    return pipes


def _run(fleet, sizes, label):
    prof = AppManager(fleet).run(_coupled(**sizes), validate="error")
    fed = prof.results["federation"]
    tr = fleet.staging.planner.summary()
    print(f"  {label}: ttc={prof.ttc:.1f}s n_failed={prof.n_failed} "
          f"dispatch={fed['dispatch']} "
          f"cross_pilot_bytes={tr['bytes_cross_pilot']}")
    assert prof.n_failed == 0
    fleet.close()
    return prof, fed


def main(fast=False):
    sizes = FAST if fast else FULL

    print("== 1) one pilot (the baseline the app was written against) ==")
    f1 = build_fleet(1, slots=PILOT_SLOTS, slots_per_pod=2,
                     journal_base="federated_1p")
    base, _ = _run(f1, sizes, "1 pilot ")

    print("== 2) two pilots, same app: late-binding dispatch spreads the "
          "stream ==")
    f2 = build_fleet(2, slots=PILOT_SLOTS, slots_per_pod=2,
                     journal_base="federated_2p")
    prof2, fed2 = _run(f2, sizes, "2 pilots")
    assert len(fed2["dispatch"]) == 2, "one pilot got every task"
    speedup = base.ttc / max(prof2.ttc, 1e-9)
    print(f"  speedup over one pilot: {speedup:.2f}x")
    assert speedup > 1.3, f"federation speedup only {speedup:.2f}x"

    print("== 3) elastic fleet: a Recruiter grows it against the "
          "backlog ==")
    rec = Recruiter(min_pilots=1, max_pilots=4,
                    slots_per_pilot=PILOT_SLOTS,
                    budget_slots=4 * PILOT_SLOTS,
                    hysteresis_s=2.0 if fast else 6.0,
                    spinup_s=1.0 if fast else 3.0,
                    grow_backlog_factor=1.5)
    fe = build_fleet(1, slots=PILOT_SLOTS, slots_per_pod=2,
                     journal_base="federated_elastic", recruiter=rec)
    _, fede = _run(fe, sizes, "elastic ")
    s = fede["recruiter"]
    print(f"  recruiter: {s['n_spawned']} spawned, {s['n_joined']} joined,"
          f" {s['n_retired']} retired, {s['direction_flips']} thrash flips")
    assert s["n_joined"] >= 1, "recruiter never grew the fleet"
    assert s["direction_flips"] == 0, "recruiter oscillated"

    print("== 4) whole-pilot failure mid-run: retries land on the "
          "survivor ==")
    fk = build_fleet(2, slots=PILOT_SLOTS, slots_per_pod=2,
                     journal_base="federated_chaos", max_retries=3)
    killed = {}

    def chaos(rt, graph, now):
        if now >= 2.0 and not killed:
            killed["t"] = now
            fk.inject_pilot_failure("p2")
    for rt in fk.pilots.values():
        rt.on_schedule = chaos
    prof, fed = _run(fk, sizes, "chaos   ")
    assert killed and prof.n_pod_lost > 0, "the kill missed all work"
    assert fed["dispatch"], "no dispatch record"
    print(f"  pilot p2 died at v={killed['t']:g}s: "
          f"{prof.n_pod_lost} attempts lost, {prof.n_retries} retried, "
          f"0 permanently failed")

    if os.environ.get("REPRO_JOURNAL_DIR"):
        print(f"  journals in {os.environ['REPRO_JOURNAL_DIR']} "
              "(one per pilot + one per fleet)")


def validate_only(fast=False) -> int:
    """Pre-flight lint of the federated app: the fleet-aware placement
    checks (E114/W202) and the recruiter configuration check (W205) run
    against the actual fleet the app would use."""
    from repro.analysis import validate_app
    rec = Recruiter(min_pilots=1, max_pilots=4,
                    slots_per_pilot=PILOT_SLOTS,
                    budget_slots=4 * PILOT_SLOTS,
                    hysteresis_s=6.0, spinup_s=3.0)
    fleet = build_fleet(2, slots=PILOT_SLOTS, slots_per_pod=2,
                        recruiter=rec)
    report = validate_app(_coupled(**(FAST if fast else FULL)),
                          runtime=fleet)
    print(report.format())
    fleet.close()
    return 0 if report.ok else 1


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="small sizes (CI smoke)")
    ap.add_argument("--validate-only", action="store_true",
                    help="lint the app against the fleet and exit (no run)")
    args = ap.parse_args()
    if args.validate_only:
        sys.exit(validate_only(fast=args.fast))
    main(fast=args.fast)
