"""End-to-end training driver: a ~100M-parameter gemma2-family model trained
for a few hundred steps on synthetic data, with checkpointing + restart.

    PYTHONPATH=src python examples/train_100m.py --steps 300      # full
    PYTHONPATH=src python examples/train_100m.py --quick          # smoke
"""
import argparse
import time

import jax

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.checkpoint import Checkpointer
from repro.data import SyntheticLM
from repro.train import TrainHyper, build_train_step, make_train_state


def model_100m():
    """~110M params, gemma2 family structure."""
    return get_config("gemma2-2b").replace(
        name="gemma2-100m",
        num_layers=10,
        d_model=640,
        num_heads=8,
        num_kv_heads=4,
        head_dim=64,
        d_ff=2560,
        vocab_size=50_257,
        sliding_window=256,
        dtype="float32",
        param_dtype="float32",
        remat="none",
        microbatches=1,
        loss_chunk=256,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()
    if args.quick:
        args.steps, args.batch, args.seq = 10, 2, 128

    cfg = model_100m()
    n = cfg.param_count()
    print(f"model: {cfg.name}  params={n/1e6:.1f}M")

    shape = ShapeSpec("drv", "train", args.seq, args.batch)
    hyper = TrainHyper(base_lr=6e-4, warmup=20, total_steps=args.steps,
                       schedule="cosine")
    step = jax.jit(build_train_step(cfg, hyper=hyper), donate_argnums=0)
    ck = Checkpointer(args.ckpt_dir, keep=2)

    if ck.latest_step() is not None:
        state, start = ck.restore(jax.eval_shape(
            lambda: make_train_state(cfg, jax.random.PRNGKey(0))))
        print(f"restored checkpoint at step {start}")
    else:
        state, start = make_train_state(cfg, jax.random.PRNGKey(0)), 0

    data = SyntheticLM(cfg, shape, seed=0)
    t0 = time.time()
    tokens_per_step = args.batch * args.seq
    for i, batch in enumerate(data.batches(start=start)):
        s = start + i
        if s >= args.steps:
            break
        state, m = step(state, batch)
        if s % 10 == 0 or s == args.steps - 1:
            loss = float(m["loss"])
            dt = time.time() - t0
            tps = tokens_per_step * (i + 1) / max(dt, 1e-9)
            print(f"step {s:4d}  loss {loss:.4f}  lr {float(m['lr']):.2e}  "
                  f"gnorm {float(m['grad_norm']):.3f}  tok/s {tps:,.0f}")
        if s and s % args.ckpt_every == 0:
            ck.save(state, s, blocking=False)
    ck.save(state, args.steps)
    ck.wait()
    print(f"done in {time.time()-t0:.1f}s; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
