"""Ensemble-at-fleet-scale dry-run: the pilot is the multi-pod mesh; each
replica-exchange member gets ONE POD as its slot (submesh), and the member's
distributed train step is lowered+compiled against that submesh.

This is the paper's core decoupling at production scale, expressed through
the PST API: the resource handler acquires 512 chips once; a SlotTopology
carves them into pod-sized slots; the PST AppManager schedules one member
task per slot, and each task builds its 256-chip mesh from the slot ids the
scheduler granted it — ``ctx["submesh"]`` is ``PilotRuntime.submesh_for``
of the running task, so placement is decided by the pilot, not the member.

    PYTHONPATH=src python examples/ensemble_dryrun.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# the XLA host-device flag must be set before jax loads: E402 is the point
import time  # noqa: E402

import jax  # noqa: E402
from repro.configs import SHAPES, get_config, input_specs  # noqa: E402
from repro.core import AppManager, Kernel, PipelineSpec, Stage, TaskSpec  # noqa: E402
from repro.core.kernel_plugin import register_kernel  # noqa: E402
from repro.dist.sharding import batch_shardings, state_shardings  # noqa: E402
from repro.dist.topology import SlotTopology  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.runtime.executor import PilotRuntime  # noqa: E402
from repro.train import build_train_step, train_state_specs  # noqa: E402


@register_kernel("dryrun.compile_member",
                 description="lower+compile one RE member on its granted "
                             "pod submesh")
def compile_member(args, ctx):
    sub = ctx["submesh"]          # the pod the pilot granted this member
    cfg = get_config(args["arch"])
    shape = SHAPES[args["shape"]]
    t0 = time.time()
    st_specs = train_state_specs(cfg)
    st_sh = state_shardings(cfg, sub, st_specs)
    b_specs = input_specs(cfg, shape)
    b_sh = batch_shardings(cfg, sub, b_specs, "train")
    step = build_train_step(cfg, sub)
    compiled = jax.jit(step, in_shardings=(st_sh, b_sh),
                       out_shardings=(st_sh, None),
                       donate_argnums=(0,)).lower(
                           st_specs, b_specs).compile()
    ma = compiled.memory_analysis()
    devs = sub.devices.ravel()
    return {"member": int(args["member"]),
            "devices": (int(devs[0].id), int(devs[-1].id)),
            "compile_s": time.time() - t0,
            "arg_mb_per_chip": ma.argument_size_in_bytes / 1e6,
            "temp_gb_per_chip": ma.temp_size_in_bytes / 1e9}


def main():
    pilot_mesh = make_production_mesh(multi_pod=True)
    print(f"pilot: {pilot_mesh.devices.size} chips, axes "
          f"{pilot_mesh.axis_names} {dict(pilot_mesh.shape)}")
    topo = SlotTopology.from_mesh(pilot_mesh, slot_axis="pod")
    print(f"slots: {topo.n_slots} pods x {topo.devices_per_slot} chips")
    runtime = PilotRuntime(mode="real", topology=topo)

    # one RE member per pod slot: the scheduler grants each task a slot id
    # and the kernel compiles the member's 256-chip train step against
    # runtime.submesh_for(task) (different pods -> different devices)
    def member_kernel(i):
        k = Kernel("dryrun.compile_member")
        k.arguments = {"arch": "gemma2-2b", "shape": "train_4k", "member": i}
        return k

    md = Stage([TaskSpec(member_kernel(i), name=f"member{i}",
                         metadata={"instance": i})
                for i in range(topo.n_slots)], name="simulation")
    am = AppManager(runtime)
    prof = am.run(PipelineSpec([md], name="re_dryrun"))
    assert prof.n_failed == 0 and prof.n_canceled == 0, [
        (t.name, t.state.value, t.error)
        for t in am.session.graph.tasks.values() if t.error]

    for i in range(topo.n_slots):
        r = prof.results["tasks"][f"member{i}"]
        print(f"member {r['member']}: pod devices "
              f"[{r['devices'][0]}..{r['devices'][1]}] "
              f"compiled in {r['compile_s']:.0f}s; "
              f"args {r['arg_mb_per_chip']:.0f} MB/chip, "
              f"temp {r['temp_gb_per_chip']:.2f} GB/chip")
    print(f"ensemble-of-pods dry-run OK: {prof.n_tasks} members ran as "
          "disjoint 256-chip SPMD programs under one pilot "
          f"(utilization {prof.utilization:.2f})")


if __name__ == "__main__":
    main()
