"""Ensemble-at-fleet-scale dry-run: the pilot is the multi-pod mesh; each
replica-exchange member gets ONE POD as its slot (submesh), and the member's
distributed train step is lowered+compiled against that submesh.

This is the paper's core decoupling at production scale: the resource
handler acquires 512 chips once; the ensemble layer schedules members onto
pod-sized slots; each member is itself a 256-chip SPMD program.

    PYTHONPATH=src python examples/ensemble_dryrun.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import time

import jax
from repro.configs import SHAPES, get_config, input_specs
from repro.dist.sharding import batch_shardings, state_shardings
from repro.dist.topology import SlotTopology
from repro.launch.mesh import make_production_mesh
from repro.train import build_train_step, train_state_specs


def pod_submeshes(mesh):
    """Split the (pod, data, model) pilot mesh into per-pod slots."""
    topo = SlotTopology.from_mesh(mesh, slot_axis="pod")
    return [topo.submesh([i]) for i in range(topo.n_slots)]


def main():
    pilot_mesh = make_production_mesh(multi_pod=True)
    print(f"pilot: {pilot_mesh.devices.size} chips, axes "
          f"{pilot_mesh.axis_names} {dict(pilot_mesh.shape)}")
    slots = pod_submeshes(pilot_mesh)
    print(f"slots: {len(slots)} pods x {slots[0].devices.size} chips")

    cfg = get_config("gemma2-2b")
    shape = SHAPES["train_4k"]

    # one RE member per pod: lower + compile the member's 256-chip train
    # step against its own submesh (different pods -> different devices)
    for i, sub in enumerate(slots):
        t0 = time.time()
        st_specs = train_state_specs(cfg)
        st_sh = state_shardings(cfg, sub, st_specs)
        b_specs = input_specs(cfg, shape)
        b_sh = batch_shardings(cfg, sub, b_specs, "train")
        step = build_train_step(cfg, sub)
        compiled = jax.jit(step, in_shardings=(st_sh, b_sh),
                           out_shardings=(st_sh, None),
                           donate_argnums=(0,)).lower(
                               st_specs, b_specs).compile()
        ma = compiled.memory_analysis()
        devs = sub.devices.ravel()
        print(f"member {i}: pod devices [{devs[0].id}..{devs[-1].id}] "
              f"compiled in {time.time()-t0:.0f}s; "
              f"args {ma.argument_size_in_bytes/1e6:.0f} MB/chip, "
              f"temp {ma.temp_size_in_bytes/1e9:.2f} GB/chip")
    print("ensemble-of-pods dry-run OK: members are disjoint 256-chip "
          "SPMD programs under one pilot")


if __name__ == "__main__":
    main()
