"""Online inference as a first-class ensemble workload.

A diurnal, bursty :class:`repro.serving.TrafficModel` feeds two SLA
classes of request batches through byte-metered Channels into
continuous-batching decode pipelines, co-tenant with a throughput
training ensemble on the SAME pilot:

  - ``latency`` requests carry priority 10 and, with
    ``PilotRuntime(preempt=True)``, EVICT running throughput/training
    work instead of queueing behind it (the evicted attempt requeues
    with a bumped epoch; its in-flight completion is an inert zombie);
  - ``throughput`` requests and the training stages run in the slack;
  - each class Channel declares ``capacity_bytes``: the traffic source
    parks when too many undecoded prompt-bytes sit staged (admission
    control by back-pressure rather than load shedding);
  - per-class p50/p99 latency, TTFT, goodput and decode-slot occupancy
    land in ``prof.results["serving"]``.

In DES mode (``--sim``) each serve task's duration comes from
``simulate_continuous`` — the virtual-clock cost model of the per-step
admit/evict loop — so a whole day of traffic replays in milliseconds.
In real mode the ``serve.decode`` kernel drives an actual jitted
``BatchedServer`` over a tiny transformer.

    PYTHONPATH=src python examples/serve_ensemble.py --sim
    PYTHONPATH=src python examples/serve_ensemble.py          # real decode
    PYTHONPATH=src python examples/serve_ensemble.py --validate-only

Set REPRO_JOURNAL_DIR to journal the run (the CI sanitizer gate replays
the journal's invariants with ``python -m repro.analysis sanitize``).
"""
import argparse
import sys

from repro.core import AppManager, Kernel, PipelineSpec, Stage, TaskSpec
from repro.runtime.executor import PilotRuntime
from repro.runtime.journal import journal_from_env
from repro.serving import TrafficModel, build_serving_app
from repro.staging import LocalityMap, StagingLayer

SLOTS = 8
WINDOWS = 8
CAPACITY_BYTES = 64 << 10           # per-class undecoded prompt budget
MODEL = TrafficModel(seed=11, window_s=5.0, base_rps=4.0, peak_rps=16.0,
                     period_s=120.0, burst_prob=0.1, prompt_tokens=32,
                     latency_new_tokens=8, throughput_new_tokens=24)


def build(mode, prioritize=True):
    serving, channels, metrics = build_serving_app(
        MODEL, WINDOWS, decode_slots=4, cores=2, step_cost_s=0.02,
        prefill_cost_s=0.05, capacity_bytes=CAPACITY_BYTES,
        prioritize=prioritize,
        deadlines={"latency": 8.0, "throughput": 120.0})

    def bulk(c, m):
        k = Kernel("synthetic.noop")
        k.sim_duration = 6.0
        return TaskSpec(k, name=f"train.c{c}.m{m}", sla="throughput")

    train = PipelineSpec(
        [Stage([bulk(c, m) for m in range(SLOTS - 2)], name=f"cycle{c}")
         for c in range(4)], name="train")
    return [*serving, train], channels, metrics


def validate_only(mode) -> int:
    """Pre-flight lint (E115/W206 live here); no task launches."""
    from repro.analysis import validate_app
    pipes, _, _ = build(mode)
    staging = StagingLayer(locality=LocalityMap(SLOTS,
                                                slots_per_pod=2))
    rt = PilotRuntime(slots=SLOTS, mode=mode, staging=staging)
    report = validate_app(pipes, runtime=rt)
    print(report.format())
    return 0 if report.ok else 1


def main(mode):
    staging = StagingLayer(
        locality=LocalityMap(SLOTS, slots_per_pod=2),
        threshold_bytes=1 << 10)
    rt = PilotRuntime(slots=SLOTS, mode=mode, staging=staging,
                      preempt=True,
                      journal=journal_from_env(f"serve_ensemble_{mode}"))
    am = AppManager(rt)
    pipes, channels, metrics = build(mode)
    prof = am.run(pipes, validate="error")
    metrics.install(am, prof)

    total = MODEL.total_requests(WINDOWS)
    print(f"mode={mode}: ttc={prof.ttc:.2f}s, {prof.n_tasks} tasks, "
          f"{total} requests, n_preempted={prof.n_preempted}")
    s = prof.results["serving"]
    for sla, c in sorted(s["classes"].items()):
        print(f"  {sla:<11} n={c['n']:<4} p50={c['p50_latency_s']:.2f}s "
              f"p99={c['p99_latency_s']:.2f}s "
              f"ttft_p50={c['p50_ttft_s']:.2f}s "
              f"goodput={c['goodput_tok_s']:.1f} tok/s "
              f"occupancy={c['occupancy']:.2f}")
    o = s["overall"]
    print(f"  overall: {o['tokens']} tokens, "
          f"throughput={o['throughput_tok_s']:.1f} tok/s, "
          f"goodput={o['goodput_tok_s']:.1f} tok/s")
    for sla, ch in channels.items():
        print(f"  channel serve.{sla}: peak {ch.peak_unconsumed_bytes}B "
              f"unconsumed (budget {CAPACITY_BYTES}B)")

    assert prof.n_failed == 0
    assert all(info["state"] == "done"
               for info in prof.results["pipelines"].values())
    assert sum(c["n"] for c in s["classes"].values()) == total
    for ch in channels.values():
        assert ch.peak_unconsumed_bytes <= CAPACITY_BYTES
        assert ch.n_unconsumed() == 0
    if mode == "sim":
        # the co-tenant training ensemble saturates the pilot; latency
        # arrivals must have evicted their way in rather than queueing
        assert prof.n_preempted >= 1, \
            "expected latency-class preemption under co-tenancy"
    print("serving co-tenancy: ok")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--sim", action="store_true",
                    help="DES mode: virtual-clock continuous batching")
    ap.add_argument("--validate-only", action="store_true",
                    help="lint the declared pipelines and exit (no run)")
    args = ap.parse_args()
    mode = "sim" if args.sim else "real"
    if args.validate_only:
        sys.exit(validate_only(mode))
    main(mode)
