"""Staging benchmark: locality hit-rate and t_data vs value-passing.

"Harnessing the Power of Many" shows staging policy (link vs copy vs
remote transfer) dominating ensemble TTC at scale.  This bench drives an
O(1000)-task coupled workload — P producer ensembles streaming cycle
payloads into channels consumed by P analysis pipelines — under three
data-movement policies on a pod-structured pilot:

  value      staging disabled (the pre-staging behavior): every put is
             passed by value — t_data is invisible (0) and the channels
             buffer the full payload bytes in memory
  copy       staged refs, but NO locality: every slot is its own domain
             and placement ignores replicas — transfers resolve to
             cross-pod copies (the per-transfer charge the paper's t_data
             term measures)
  locality   staged refs + pod-aware placement: consumers are granted
             slots in pods that already hold their input replicas, so
             transfers resolve to links and t_data collapses

DES mode: kernels declare ``output_nbytes`` and the staging layer stages
*virtual* refs, so transfer costs are modeled on the virtual clock without
moving payloads (scales to thousands of tasks instantly).  Without
``--sim`` a small real-mode run with actual payloads is appended, where
t_data is measured on the wall clock.

Emits BENCH_staging.json (repo root) + benchmarks/results/staging.json.
Fails loudly unless the locality policy reports hit-rate > 0 AND less
t_data than the copy policy.

    PYTHONPATH=src python -m benchmarks.staging [--fast] [--sim]
"""
from __future__ import annotations

import json
import os
from typing import Optional

from benchmarks.common import print_csv, save_results
from repro.core import AppManager, Channel, Kernel, PipelineSpec, Stage, \
    TaskSpec
from repro.runtime.executor import PilotRuntime
from repro.staging import LocalityMap, StagingLayer

SLOTS = 16
PODS = 4
MEMBER_NBYTES = 256 << 20          # declared per-member cycle output
COPY_GBPS = 25.0

FULL = dict(pipelines=4, cycles=30, members=8)      # 1080 tasks
FAST = dict(pipelines=2, cycles=6, members=4)       # 60 tasks


def _member(mode, dur=1.0, nbytes: Optional[int] = MEMBER_NBYTES,
            payload=None):
    if mode == "sim":
        k = Kernel("synthetic.noop")
        k.sim_duration = dur
        k.output_nbytes = nbytes
    else:
        k = Kernel("synthetic.echo")
        k.arguments = {"value": payload}
    return k


def build(mode, *, pipelines, cycles, members, payload_floats=0):
    pipes = []
    for p in range(pipelines):
        ch = Channel(f"traj{p}")
        payload = (lambda c, m: {"cycle": c, "member": m,
                                 "traj": [0.125] * payload_floats})
        pipes.append(PipelineSpec(
            [Stage([TaskSpec(_member(mode, payload=payload(c, m)),
                             name=f"p{p}.c{c}.m{m}")
                    for m in range(members)],
                   name=f"cycle{c}", outputs=[ch])
             for c in range(cycles)], name=f"producer{p}"))
        pipes.append(PipelineSpec(
            [Stage([TaskSpec(_member(mode, dur=0.5, nbytes=None,
                                     payload="ana"),
                             name=f"a{p}.r{c}")],
                   name=f"round{c}", inputs={"traj": ch})
             for c in range(cycles)], name=f"analysis{p}"))
    return pipes


def run_policy(policy: str, mode: str, sizes: dict) -> dict:
    if policy == "value":
        staging = None
    elif policy == "copy":
        staging = StagingLayer(
            locality=LocalityMap(SLOTS, slots_per_pod=1),
            threshold_bytes=1024, prefer_local=False, copy_gbps=COPY_GBPS)
    elif policy == "locality":
        staging = StagingLayer(
            locality=LocalityMap(SLOTS, slots_per_pod=SLOTS // PODS),
            threshold_bytes=1024, copy_gbps=COPY_GBPS)
    else:
        raise ValueError(policy)
    rt = PilotRuntime(slots=SLOTS, mode=mode, staging=staging)
    am = AppManager(rt)
    payload_floats = 4096 if mode == "real" else 0
    prof = am.run(build(mode, **sizes, payload_floats=payload_floats))
    if prof.n_failed:
        raise SystemExit(f"{policy}/{mode}: {prof.n_failed} failed tasks")

    tasks = am.session.graph.tasks.values()
    per_task = sorted(t.t_data for t in tasks if t.t_data)
    n_puts = sizes["pipelines"] * sizes["cycles"]
    row = {"policy": policy, "mode": mode,
           "n_tasks": prof.n_tasks, "ttc": round(prof.ttc, 3),
           "t_data_total": round(prof.t_data, 4),
           "t_data_per_task_mean": round(
               sum(per_task) / len(per_task), 5) if per_task else 0.0,
           "t_data_per_task_max": round(per_task[-1], 5)
           if per_task else 0.0,
           "n_tasks_charged": len(per_task)}
    if staging is None:
        # value passing: the traffic exists but is invisible — model what
        # the channels buffered so the comparison is honest
        nbytes = (MEMBER_NBYTES * sizes["members"] * n_puts
                  if mode == "sim" else 0)
        row.update({"locality_hit_rate": None,
                    "bytes_by_value": nbytes})
    else:
        tr = staging.planner.summary()
        row.update({"locality_hit_rate": tr["locality_hit_rate"],
                    "links": tr["link"], "copies": tr["copy"],
                    "materializes": tr["materialize"],
                    "bytes_copied": tr["bytes_copied"],
                    "store_puts": staging.store.stats["puts"],
                    "dedup_hits": staging.store.stats["dedup_hits"]})
    return row


def main(fast: bool = False, sim_only: bool = False):
    sizes = FAST if fast else FULL
    rows = []
    for policy in ("value", "copy", "locality"):
        rows.append(run_policy(policy, "sim", sizes))
        r = rows[-1]
        hr = r["locality_hit_rate"]
        print(f"  {policy:>8} sim : ttc={r['ttc']:>8.1f}s "
              f"t_data={r['t_data_total']:>8.3f}s "
              f"hit_rate={'-' if hr is None else hr}")
    if not sim_only:
        small = dict(FAST) if not fast else sizes
        rows.append(run_policy("locality", "real", small))
        r = rows[-1]
        print(f"  locality real: ttc={r['ttc']:>8.3f}s "
              f"t_data={r['t_data_total']:>8.4f}s "
              f"hit_rate={r['locality_hit_rate']}")

    by = {(r["policy"], r["mode"]): r for r in rows}
    loc, cop = by[("locality", "sim")], by[("copy", "sim")]
    summary = {
        "locality_hit_rate": loc["locality_hit_rate"],
        "t_data_locality_over_copy": round(
            loc["t_data_total"] / max(cop["t_data_total"], 1e-12), 4),
        "copies_avoided": cop["copies"] - loc["copies"],
        "value_passing_buffered_bytes":
            by[("value", "sim")]["bytes_by_value"]}
    out = {"slots": SLOTS, "pods": PODS,
           "member_output_nbytes": MEMBER_NBYTES,
           "copy_gbps": COPY_GBPS, "rows": rows, "summary": summary}

    save_results("staging", rows)
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    with open(os.path.join(root, "BENCH_staging.json"), "w") as f:
        json.dump(out, f, indent=1)
    print_csv("staging", rows,
              ["policy", "mode", "n_tasks", "ttc", "t_data_total",
               "t_data_per_task_mean", "locality_hit_rate"])
    print(f"\nsummary: {json.dumps(summary)}")

    if not loc["locality_hit_rate"] or loc["locality_hit_rate"] <= 0:
        raise SystemExit("locality policy produced no pod-local links")
    if loc["t_data_total"] >= cop["t_data_total"]:
        raise SystemExit(
            f"locality t_data {loc['t_data_total']} not below copy "
            f"baseline {cop['t_data_total']}")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="small sizes (CI smoke)")
    ap.add_argument("--sim", action="store_true",
                    help="DES rows only (no real-mode run)")
    a = ap.parse_args()
    main(fast=a.fast, sim_only=a.sim)
