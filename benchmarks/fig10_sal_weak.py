"""Fig. 10 reproduction: SAL weak scaling — simulations = slots, 64..1024.
Expected: simulation phase constant; analysis grows with #simulations."""
from __future__ import annotations

from benchmarks.common import print_csv, save_results
from benchmarks.fig9_sal_strong import SALScaling
from repro.core import SingleClusterEnvironment

SCALES = (64, 128, 256, 512, 1024)


def run(scales=SCALES, iters=1) -> list:
    rows = []
    for n in scales:
        cl = SingleClusterEnvironment(resource="local.cpu", cores=n,
                                      walltime=600, mode="sim")
        cl.allocate()
        prof = cl.run(SALScaling(iters, n, 1))
        cl.deallocate()
        st = prof.per_stage
        rows.append({
            "cores": n, "simulations": n,
            "ttc_virtual": round(prof.ttc, 3),
            "pre_loop": round(st.get("pre_loop", {}).get("t_exec", 0.0), 3),
            "sim_phase": round(
                st.get("simulation", {}).get("t_exec", 0.0) / n, 3),
            "analysis_phase": round(
                st.get("analysis", {}).get("t_exec", 0.0), 3),
            "t_rts_overhead_real": round(prof.t_rts_overhead, 4),
            "utilization": round(prof.utilization, 4)})
    return rows


def main(fast: bool = False):
    rows = run((64, 256) if fast else SCALES)
    save_results("fig10_sal_weak", rows)
    print_csv("fig10_sal_weak", rows,
              ["cores", "simulations", "ttc_virtual", "pre_loop",
               "sim_phase", "analysis_phase", "t_rts_overhead_real",
               "utilization"])
    return rows


if __name__ == "__main__":
    main()
