"""Beyond-paper benchmark: per-cycle dispatch overhead, task mode vs fused
SPMD mode.

Task mode pays O(N) scheduling+dispatch per cycle (the paper's per-task
overhead, its Fig.5 dominant term).  Fused mode launches ONE jit'd program
per cycle regardless of N, with the exchange on-device.  This table is the
quantitative argument for the TPU-native ensemble execution path."""
from __future__ import annotations

import time

import jax

from benchmarks.common import print_csv, save_results
from repro.configs import get_config, reduced
from repro.configs.base import ShapeSpec
from repro.core import (FusedEnsemble, Kernel, ReplicaExchange,
                        SingleClusterEnvironment)

SHAPE = ShapeSpec("bench", "train", 32, 2)


class TaskModeRE(ReplicaExchange):
    def __init__(self, cycles, replicas, ens):
        super().__init__(cycles, replicas)
        self.ens = ens
        self.temps = [3e-4 * 1.3 ** i for i in range(replicas)]

    def prepare_replica_for_md(self, r):
        k = Kernel("lm.train")
        k.arguments = {"arch": "reduced:gemma2-2b", "steps": 2,
                       "member": r.id, "ensemble": self.ens,
                       "lr": self.temps[r.id], "batch": 2, "seq": 32}
        return k

    def prepare_exchange(self, replicas):
        k = Kernel("re.exchange")
        k.arguments = {"replicas": len(replicas),
                       "cycle": replicas[0].cycle, "temps": self.temps,
                       "ensemble": self.ens}
        return k

    def apply_exchange(self, result, replicas):
        self.temps = result["temps"]


def run(members=(2, 4, 8, 16), cycles: int = 2) -> list:
    cfg = reduced(get_config("gemma2-2b"))
    rows = []
    for n in members:
        # ---- task mode -----------------------------------------------------
        cl = SingleClusterEnvironment(cores=n, walltime=10)
        cl.allocate()
        prof = cl.run(TaskModeRE(cycles, n, ens=f"fd{n}"))
        cl.deallocate()
        task_dispatch = (prof.t_rts_overhead + prof.t_pattern_overhead) \
            / cycles

        # ---- fused mode -----------------------------------------------------
        fe = FusedEnsemble(cfg, n)
        cyc = fe._build_cycle(2, SHAPE)
        from repro.core.ensemble import _stack_steps
        from repro.data import SyntheticLM
        import jax.numpy as jnp
        data = [SyntheticLM(cfg, SHAPE, seed=i) for i in range(n)]
        batches = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[_stack_steps(data[i], 0, 2) for i in range(n)])
        ens = fe.init(jax.random.PRNGKey(0))
        ens, m = cyc(ens, batches, jax.random.PRNGKey(1))  # compile warm-up
        jax.block_until_ready(m["losses"])
        key = jax.random.PRNGKey(2)
        # measure dispatch (host) time: call until async dispatch returns
        t0 = time.perf_counter()
        ens2, m = cyc(ens, batches, key)
        dispatch = time.perf_counter() - t0   # includes device wait on CPU
        jax.block_until_ready(m["losses"])
        total = time.perf_counter() - t0

        rows.append({"members": n,
                     "task_dispatch_per_cycle_s": round(task_dispatch, 5),
                     "task_dispatch_per_member_ms":
                         round(1e3 * task_dispatch / n, 3),
                     "fused_dispatch_per_cycle_s": round(dispatch, 5),
                     "fused_total_per_cycle_s": round(total, 5)})
    return rows


def main(fast: bool = False):
    rows = run((2, 4) if fast else (2, 4, 8, 16))
    save_results("fused_dispatch", rows)
    print_csv("fused_dispatch", rows,
              ["members", "task_dispatch_per_cycle_s",
               "task_dispatch_per_member_ms", "fused_dispatch_per_cycle_s",
               "fused_total_per_cycle_s"])
    return rows


if __name__ == "__main__":
    main()
