"""Frontier-scheduler microbenchmark: incremental ready-set vs full scan.

RADICAL-Pilot's characterization shows scheduler event handling dominating
at O(10k+) tasks; the seed's ``TaskGraph.ready()`` re-scanned every task on
every completion event (O(n²) over a session).  The redesigned graph
(runtime/states.py) maintains the frontier incrementally — this bench
drives the DES executor over bag and chain workloads and reports completion
events/sec for:

  new     the incremental frontier (pop_ready/requeue + O(1) done())
  legacy  a reference implementation of the seed's full-scan behavior,
          run at smaller sizes (it would take minutes at 100k)

Linear scaling criterion: the "new" events/sec stays flat as n grows
(events_per_sec ratio largest/smallest size ~ 1); the legacy events/sec
collapses ~ 1/n.  Emits BENCH_frontier.json (repo root) and
benchmarks/results/frontier.json.

    PYTHONPATH=src python -m benchmarks.frontier [--fast]
"""
from __future__ import annotations

import json
import os
import time
from typing import List, Optional

from benchmarks.common import print_csv, save_results
from repro.runtime.executor import PilotRuntime
from repro.runtime.states import Task, TaskGraph, TaskState

NEW_SIZES = (1_000, 10_000, 100_000)
LEGACY_SIZES = (500, 2_000, 4_000)    # quadratic: 4k already takes ~20s
FAST_NEW = (1_000, 10_000)
FAST_LEGACY = (250, 1_000)
SLOTS = 64


class _LegacyScanGraph(TaskGraph):
    """The seed's cost model: every scheduling step re-derives the ready
    set by scanning all tasks, and done() scans for terminal states."""

    def pop_ready(self) -> Optional[Task]:
        best = None
        for t in self.tasks.values():
            if t.state == TaskState.NEW and all(
                    self.tasks[d].state == TaskState.DONE for d in t.deps):
                if best is None or t.tid < best.tid:
                    best = t
        return best

    def requeue(self, task: Task):
        pass                      # never left any structure

    def done(self) -> bool:
        return all(t.state.terminal for t in self.tasks.values())


def build(graph_cls, shape: str, n: int) -> TaskGraph:
    g = graph_cls()
    for i in range(n):
        deps: List[str] = []
        if shape == "chain" and i:
            deps = [f"t{i - 1:06d}"]
        elif shape == "fan" and i:
            deps = [f"t{(i - 1) // 4:06d}"]   # 4-ary tree: mixed frontier
        g.add(Task(name=f"t{i:06d}", duration=1.0, deps=deps, stage="s"))
    return g


def run_one(impl: str, shape: str, n: int, *, traced: bool = False) -> dict:
    graph_cls = TaskGraph if impl in ("new", "new+trace") \
        else _LegacyScanGraph
    g = build(graph_cls, shape, n)
    tracer = None
    if traced:
        from repro.obs import Tracer
        tracer = Tracer()
    rt = PilotRuntime(slots=SLOTS, mode="sim", tracer=tracer)
    t0 = time.perf_counter()
    prof = rt.run(g)
    dt = time.perf_counter() - t0
    if prof.n_failed or prof.n_canceled or prof.n_tasks != n:
        raise SystemExit(f"{impl}/{shape}@{n}: bad run")
    if traced and len(tracer.spans) != n:
        raise SystemExit(f"{impl}/{shape}@{n}: {len(tracer.spans)} spans "
                         f"for {n} tasks")
    return {"impl": impl, "shape": shape, "n_tasks": n,
            "seconds": round(dt, 4),
            "events_per_sec": round(n / dt, 1),
            "t_rts_overhead": round(prof.t_rts_overhead, 4)}


def main(fast: bool = False):
    rows = []
    new_sizes = FAST_NEW if fast else NEW_SIZES
    legacy_sizes = FAST_LEGACY if fast else LEGACY_SIZES
    for shape in ("bag", "chain", "fan"):
        for n in new_sizes:
            rows.append(run_one("new", shape, n))
            print(f"  new    {shape:>5} n={n:>7}: "
                  f"{rows[-1]['events_per_sec']:>10.0f} events/s")
        # legacy reference only on bag (its worst case is shape-independent
        # — every event re-scans all n tasks)
        for n in (legacy_sizes if shape == "bag" else ()):
            rows.append(run_one("legacy", shape, n))
            print(f"  legacy {shape:>5} n={n:>7}: "
                  f"{rows[-1]['events_per_sec']:>10.0f} events/s")

    # tracing overhead: the flight recorder (repro.obs.Tracer) must stay
    # near-zero-cost — traced events/s within 10% of untraced, best of 5
    # each, arms alternated so clock-frequency drift hits both equally,
    # at the largest bag size
    n_trace = max(new_sizes)
    un_runs, tr_runs = [], []
    for _ in range(5):
        un_runs.append(run_one("new", "bag", n_trace)["events_per_sec"])
        tr_runs.append(run_one("new+trace", "bag", n_trace,
                               traced=True)["events_per_sec"])
    untraced, traced = max(un_runs), max(tr_runs)
    rows.append({"impl": "new+trace", "shape": "bag", "n_tasks": n_trace,
                 "seconds": round(n_trace / traced, 4),
                 "events_per_sec": traced, "t_rts_overhead": None})
    trace_ratio = traced / untraced
    print(f"  tracing {n_trace} tasks: {traced:.0f} traced vs "
          f"{untraced:.0f} untraced events/s (ratio {trace_ratio:.3f})")
    if trace_ratio < 0.9:
        raise SystemExit(
            f"tracing overhead exceeds 10%: {traced:.0f} traced vs "
            f"{untraced:.0f} untraced events/s (ratio {trace_ratio:.3f})")

    # scaling summary: events/sec at the largest size over the smallest —
    # ~1.0 means per-event cost independent of n (linear total)
    summary = {}
    for impl, sizes in (("new", new_sizes), ("legacy", legacy_sizes)):
        bag = {r["n_tasks"]: r["events_per_sec"] for r in rows
               if r["impl"] == impl and r["shape"] == "bag"}
        summary[impl] = {
            "events_per_sec_ratio_large_over_small":
                round(bag[max(sizes)] / bag[min(sizes)], 3),
            "max_n": max(sizes)}
    summary["tracing"] = {
        "events_per_sec_traced": traced,
        "events_per_sec_untraced": untraced,
        "ratio": round(trace_ratio, 3)}
    out = {"slots": SLOTS, "rows": rows, "summary": summary}

    save_results("frontier", rows)
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    with open(os.path.join(root, "BENCH_frontier.json"), "w") as f:
        json.dump(out, f, indent=1)
    print_csv("frontier", rows,
              ["impl", "shape", "n_tasks", "seconds", "events_per_sec"])
    print(f"\nscaling summary: {json.dumps(summary)}")
    ratio = summary["new"]["events_per_sec_ratio_large_over_small"]
    if not fast and ratio < 0.4:
        raise SystemExit(
            f"frontier maintenance is not linear: events/sec fell to "
            f"{ratio:.2f}x from {min(new_sizes)} to {max(new_sizes)} tasks")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="small sizes only (CI smoke)")
    main(fast=ap.parse_args().fast)
