"""Fig. 5 reproduction: the character-count application under all three
execution patterns, tasks = cores in {24, 48, 96, 192}, with the paper's TTC
decomposition (T_EnMD = T_core + T_pattern + T_RTS; + T_exec, T_data).

The paper's claim validated here: execution time is invariant across
patterns for the same workload, and the EnMD overheads are small and
pattern-independent (the RP/DB overhead, dominant in the paper, collapses to
the local-journal RTS overhead here — same decomposition, µs-ms magnitudes;
DESIGN.md §8.2)."""
from __future__ import annotations

import os

from benchmarks.common import CharCountApp, print_csv, save_results
from repro.core import (Kernel, Pipeline, ReplicaExchange,
                        SimulationAnalysisLoop, SingleClusterEnvironment)

SCALES = (24, 48, 96, 192)
SIM_TASK_SECONDS = 0.05      # modeled task duration for --sim (DES) runs


def _sim(k: Kernel, sim_mode: bool) -> Kernel:
    if sim_mode:
        k.sim_duration = SIM_TASK_SECONDS
    return k


class CCPipeline(Pipeline):
    sim_mode = False

    def stage_1(self, i):
        return _sim(CharCountApp.mkfile_kernel(i), self.sim_mode)

    def stage_2(self, i):
        return _sim(CharCountApp.ccount_kernel(i), self.sim_mode)


class CCRE(ReplicaExchange):
    """Two-stage toy as one RE cycle: md=mkfile, exchange=aggregate ccount."""
    sim_mode = False

    def prepare_replica_for_md(self, r):
        return _sim(CharCountApp.mkfile_kernel(r.id), self.sim_mode)

    def prepare_exchange(self, replicas):
        return _sim(Kernel("misc.ccount"), self.sim_mode)


class CCSAL(SimulationAnalysisLoop):
    sim_mode = False

    def simulation_stage(self, it, i):
        return _sim(CharCountApp.mkfile_kernel(i), self.sim_mode)

    def analysis_stage(self, it, j):
        return _sim(CharCountApp.ccount_kernel(j), self.sim_mode)


def run(scales=SCALES, mode: str = "real") -> list:
    rows = []
    CCPipeline.sim_mode = CCRE.sim_mode = CCSAL.sim_mode = (mode == "sim")
    for n in scales:
        for pname, make in (
                ("pipeline", lambda: CCPipeline(stages=2, instances=n)),
                ("re", lambda: CCRE(cycles=1, replicas=n)),
                ("sal", lambda: CCSAL(maxiterations=1,
                                      simulation_instances=n,
                                      analysis_instances=n))):
            # REPRO_JOURNAL_DIR (set in CI) journals every run so the
            # sanitizer gate can replay the invariants; names are distinct
            # per cell to keep restart-replay from crossing runs
            cl = SingleClusterEnvironment(
                resource="local.cpu", cores=n, walltime=10, mode=mode,
                database_url=os.environ.get("REPRO_JOURNAL_DIR"),
                database_name=f"fig5_{pname}_{n}_{mode}")
            cl.allocate()
            prof = cl.run(make())
            cl.deallocate()
            if prof.n_failed or prof.n_canceled:
                raise SystemExit(f"{pname}@{n}: {prof.n_failed} failed, "
                                 f"{prof.n_canceled} canceled")
            rows.append({"pattern": pname, "tasks_cores": n,
                         "n_tasks": prof.n_tasks,
                         **{k: round(v, 6) for k, v in
                            prof.summary().items()
                            if isinstance(v, float)},
                         "t_enmd_overhead": round(prof.t_enmd_overhead, 6)})
    return rows


def main(fast: bool = False, mode: str = "real"):
    rows = run((24, 48) if fast else SCALES, mode=mode)
    save_results("fig5_patterns", rows)
    print_csv("fig5_patterns", rows,
              ["pattern", "tasks_cores", "ttc", "t_exec",
               "t_core_overhead", "t_pattern_overhead", "t_rts_overhead",
               "t_data"])
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="small scales only (CI smoke)")
    ap.add_argument("--sim", action="store_true",
                    help="DES mode: modeled task durations, real overheads")
    args = ap.parse_args()
    main(fast=args.fast, mode="sim" if args.sim else "real")
