"""Fig. 5 reproduction: the character-count application under all three
execution patterns, tasks = cores in {24, 48, 96, 192}, with the paper's TTC
decomposition (T_EnMD = T_core + T_pattern + T_RTS; + T_exec, T_data).

The paper's claim validated here: execution time is invariant across
patterns for the same workload, and the EnMD overheads are small and
pattern-independent (the RP/DB overhead, dominant in the paper, collapses to
the local-journal RTS overhead here — same decomposition, µs-ms magnitudes;
DESIGN.md §8.2)."""
from __future__ import annotations

from benchmarks.common import CharCountApp, print_csv, save_results
from repro.core import (Kernel, Pipeline, ReplicaExchange,
                        SimulationAnalysisLoop, SingleClusterEnvironment)

SCALES = (24, 48, 96, 192)


class CCPipeline(Pipeline):
    def stage_1(self, i):
        return CharCountApp.mkfile_kernel(i)

    def stage_2(self, i):
        return CharCountApp.ccount_kernel(i)


class CCRE(ReplicaExchange):
    """Two-stage toy as one RE cycle: md=mkfile, exchange=aggregate ccount."""

    def prepare_replica_for_md(self, r):
        return CharCountApp.mkfile_kernel(r.id)

    def prepare_exchange(self, replicas):
        k = Kernel("misc.ccount")
        return k


class CCSAL(SimulationAnalysisLoop):
    def simulation_stage(self, it, i):
        return CharCountApp.mkfile_kernel(i)

    def analysis_stage(self, it, j):
        return CharCountApp.ccount_kernel(j)


def run(scales=SCALES) -> list:
    rows = []
    for n in scales:
        for pname, make in (
                ("pipeline", lambda: CCPipeline(stages=2, instances=n)),
                ("re", lambda: CCRE(cycles=1, replicas=n)),
                ("sal", lambda: CCSAL(maxiterations=1,
                                      simulation_instances=n,
                                      analysis_instances=n))):
            cl = SingleClusterEnvironment(resource="local.cpu", cores=n,
                                          walltime=10)
            cl.allocate()
            prof = cl.run(make())
            cl.deallocate()
            rows.append({"pattern": pname, "tasks_cores": n,
                         "n_tasks": prof.n_tasks,
                         **{k: round(v, 6) for k, v in
                            prof.summary().items()
                            if isinstance(v, float)},
                         "t_enmd_overhead": round(prof.t_enmd_overhead, 6)})
    return rows


def main(fast: bool = False):
    rows = run((24, 48) if fast else SCALES)
    save_results("fig5_patterns", rows)
    print_csv("fig5_patterns", rows,
              ["pattern", "tasks_cores", "ttc", "t_exec",
               "t_core_overhead", "t_pattern_overhead", "t_rts_overhead",
               "t_data"])
    return rows


if __name__ == "__main__":
    main()
