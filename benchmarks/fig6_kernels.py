"""Fig. 6 reproduction: kernel-plugin swap validation.

Take the SAL pattern from Fig. 5 and replace the toy kernels with REAL
science kernels — the paper used Gromacs + LSDMap; we use an actual LM train
step (reduced gemma2 family) + an eval/analysis step.  Claim validated:
changing the kernel plugins changes T_exec but NOT the EnMD overheads."""
from __future__ import annotations

from benchmarks.common import print_csv, save_results
from repro.core import Kernel, SimulationAnalysisLoop, SingleClusterEnvironment

SCALES = (24, 48, 96, 192)


class GromacsLSDMapAnalogue(SimulationAnalysisLoop):
    """simulation = lm.train (the Gromacs analogue);
    analysis = lm.eval over the trained member (the LSDMap analogue)."""

    def __init__(self, maxiterations, simulation_instances,
                 analysis_instances, ens="fig6"):
        super().__init__(maxiterations, simulation_instances,
                         analysis_instances)
        self.ens = ens

    def simulation_stage(self, it, i):
        k = Kernel("lm.train")
        k.arguments = {"arch": "reduced:gemma2-2b", "steps": 1, "member": i,
                       "ensemble": self.ens, "batch": 2, "seq": 32}
        return k

    def analysis_stage(self, it, j):
        k = Kernel("lm.eval")
        k.arguments = {"arch": "reduced:gemma2-2b", "member": j,
                       "ensemble": self.ens, "batch": 2, "seq": 32}
        return k


def run(scales=SCALES) -> list:
    rows = []
    for n in scales:
        cl = SingleClusterEnvironment(resource="local.cpu", cores=n,
                                      walltime=10)
        cl.allocate()
        app = GromacsLSDMapAnalogue(1, n, min(n, 4), ens=f"fig6_{n}")
        prof = cl.run(app)
        cl.deallocate()
        rows.append({"pattern": "sal+lm", "tasks_cores": n,
                     "n_tasks": prof.n_tasks,
                     **{k: round(v, 6) for k, v in prof.summary().items()
                        if isinstance(v, float)},
                     "t_enmd_overhead": round(prof.t_enmd_overhead, 6)})
    return rows


def main(fast: bool = False):
    rows = run((8, 16) if fast else SCALES)
    save_results("fig6_kernels", rows)
    print_csv("fig6_kernels", rows,
              ["pattern", "tasks_cores", "ttc", "t_exec", "t_core_overhead",
               "t_pattern_overhead", "t_rts_overhead", "t_data"])
    return rows


if __name__ == "__main__":
    main()
