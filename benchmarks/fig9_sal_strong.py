"""Fig. 9 reproduction: SAL strong scaling — 1024 simulations (Amber-CoCo
analogue), 64..1024 slots.  pre_loop is orders slower than per-iteration
stages (paper's dual-axis figure); analysis runs serially over simulations.
"""
from __future__ import annotations

from benchmarks.common import print_csv, save_results
from repro.core import Kernel, SimulationAnalysisLoop, SingleClusterEnvironment

SIMS = 1024
SLOTS = (64, 128, 256, 512, 1024)
SIM_SECONDS = 60.0           # calibrated 0.6 ps Amber segment
ANA_PER_SIM = 0.05           # serial CoCo analysis per simulation
PRE_SECONDS = 600.0          # pre-loop setup (paper: orders larger)


class SALScaling(SimulationAnalysisLoop):
    def __init__(self, maxiterations, simulation_instances,
                 analysis_instances=1):
        super().__init__(maxiterations, simulation_instances,
                         analysis_instances)

    def pre_loop(self):
        k = Kernel("synthetic.noop")
        k.sim_duration = PRE_SECONDS
        return k

    def simulation_stage(self, it, i):
        k = Kernel("synthetic.noop")
        k.sim_duration = SIM_SECONDS
        return k

    def analysis_stage(self, it, j):
        k = Kernel("synthetic.noop")
        k.sim_duration = ANA_PER_SIM * self.simulation_instances
        return k


def run(slots=SLOTS, sims=SIMS, iters=1) -> list:
    rows = []
    for n in slots:
        cl = SingleClusterEnvironment(resource="local.cpu", cores=n,
                                      walltime=600, mode="sim")
        cl.allocate()
        prof = cl.run(SALScaling(iters, sims, 1))
        cl.deallocate()
        st = prof.per_stage
        rows.append({
            "cores": n, "simulations": sims,
            "ttc_virtual": round(prof.ttc, 3),
            "pre_loop": round(st.get("pre_loop", {}).get("t_exec", 0.0), 3),
            "sim_phase": round(
                st.get("simulation", {}).get("t_exec", 0.0) / n, 3),
            "analysis_phase": round(
                st.get("analysis", {}).get("t_exec", 0.0), 3),
            "t_rts_overhead_real": round(prof.t_rts_overhead, 4),
            "t_pattern_overhead_real": round(prof.t_pattern_overhead, 4),
            "utilization": round(prof.utilization, 4)})
    return rows


def main(fast: bool = False):
    rows = run(slots=(64, 256) if fast else SLOTS,
               sims=256 if fast else SIMS)
    save_results("fig9_sal_strong", rows)
    print_csv("fig9_sal_strong", rows,
              ["cores", "simulations", "ttc_virtual", "pre_loop",
               "sim_phase", "analysis_phase", "t_rts_overhead_real",
               "utilization"])
    return rows


if __name__ == "__main__":
    main()
