"""Serving benchmark: SLA-priority scheduling vs no-priority co-tenancy.

A day of diurnal, bursty traffic (O(100k) requests regenerated from a
seedable TrafficModel — the DES runs O(windows) tasks, not O(requests))
is served by continuous-batching decode pipelines co-tenant with a
throughput training bag on the same pilot, in three rows:

  baseline   SLA annotations stripped, no preemption: latency requests
             queue FIFO behind throughput decode + training work
  priority   latency class at priority 10 with PilotRuntime(preempt=True):
             arrivals evict running throughput attempts (requeued, no
             retry spent) instead of waiting for slots
  fleet2     the priority row on a 2-pilot federation (late-binding
             dispatch spreads serve + train load)

Each class Channel declares ``capacity_bytes``: when decode falls behind,
the traffic source PARKS on unconsumed staged prompt-bytes (admission
control by back-pressure), and the bench asserts the budget held for the
whole run.  Fails loudly unless priority scheduling cuts latency-class
p99 by >= 2x at <= 10% overall goodput cost vs baseline.

Emits BENCH_serve.json (repo root) + benchmarks/results/serve.json.

    PYTHONPATH=src python -m benchmarks.serve [--fast] [--sim]
"""
from __future__ import annotations

import json
import os

from benchmarks.common import print_csv, save_results
from repro.core import AppManager, Kernel, PipelineSpec, Stage, TaskSpec
from repro.runtime.executor import PilotRuntime
from repro.runtime.journal import journal_from_env
from repro.serving import TrafficModel, build_serving_app
from repro.staging import LocalityMap, StagingLayer

SLOTS = 8
SLOTS_PER_POD = 2
CAPACITY_BYTES = 256 << 10          # per-class undecoded prompt budget
DEADLINES = {"latency": 15.0, "throughput": 3600.0}

FULL = dict(windows=1250, train_tasks=320)      # ~100k requests
FAST = dict(windows=60, train_tasks=90)         # ~4.8k requests (CI)

MODEL_ARGS = dict(window_s=10.0, base_rps=4.0, peak_rps=12.0,
                  period_s=3600.0, burst_prob=0.03, burst_mult=4.0,
                  latency_frac=0.25, prompt_tokens=128,
                  latency_new_tokens=16, throughput_new_tokens=96)
SERVE_ARGS = dict(decode_slots=16, cores=2, step_cost_s=0.02,
                  prefill_cost_s=0.05)


def build(windows: int, train_tasks: int, *, prioritize: bool):
    model = TrafficModel(seed=42, **MODEL_ARGS)
    serving, channels, metrics = build_serving_app(
        model, windows, capacity_bytes=CAPACITY_BYTES,
        prioritize=prioritize, deadlines=DEADLINES, **SERVE_ARGS)

    def bulk(i):
        k = Kernel("synthetic.noop")
        k.sim_duration = 45.0
        return TaskSpec(k, name=f"train.{i:05d}",
                        sla="throughput" if prioritize else None)

    train = PipelineSpec(
        [Stage([bulk(i) for i in range(train_tasks)], name="bag")],
        name="train")
    return model, [*serving, train], channels, metrics


def _row(tag, model, windows, prof, channels, metrics, am) -> dict:
    metrics.install(am, prof)
    s = prof.results["serving"]
    lat, thr = s["classes"]["latency"], s["classes"]["throughput"]
    peak = max(ch.peak_unconsumed_bytes for ch in channels.values())
    return {"config": tag, "n_requests": model.total_requests(windows),
            "n_tasks": prof.n_tasks, "ttc": round(prof.ttc, 1),
            "n_preempted": prof.n_preempted,
            "lat_p50": round(lat["p50_latency_s"], 2),
            "lat_p99": round(lat["p99_latency_s"], 2),
            "lat_ttft_p50": round(lat["p50_ttft_s"], 2),
            "thr_p99": round(thr["p99_latency_s"], 2),
            "goodput_tok_s": round(s["overall"]["goodput_tok_s"], 1),
            "throughput_tok_s": round(s["overall"]["throughput_tok_s"], 1),
            "occupancy": round(thr["occupancy"], 3),
            "peak_channel_bytes": peak,
            "serving": s}


def run_pilot(tag: str, sizes: dict, *, prioritize: bool) -> dict:
    staging = StagingLayer(
        locality=LocalityMap(SLOTS, slots_per_pod=SLOTS_PER_POD),
        threshold_bytes=1 << 10)
    rt = PilotRuntime(slots=SLOTS, mode="sim", staging=staging,
                      preempt=prioritize,
                      journal=journal_from_env(f"serve-{tag}"))
    am = AppManager(rt)
    model, pipes, channels, metrics = build(sizes["windows"],
                                            sizes["train_tasks"],
                                            prioritize=prioritize)
    prof = am.run(pipes, validate="error")
    if prof.n_failed:
        raise SystemExit(f"{tag}: {prof.n_failed} failed tasks")
    return _row(tag, model, sizes["windows"], prof, channels, metrics, am)


def run_fleet2(sizes: dict) -> dict:
    from repro.federation import build_fleet
    fleet = build_fleet(2, slots=SLOTS, mode="sim",
                        slots_per_pod=SLOTS_PER_POD,
                        journal_base="serve-fleet2", preempt=True)
    am = AppManager(fleet)
    model, pipes, channels, metrics = build(sizes["windows"],
                                            sizes["train_tasks"],
                                            prioritize=True)
    prof = am.run(pipes, validate="error")
    if prof.n_failed:
        raise SystemExit(f"fleet2: {prof.n_failed} failed tasks")
    row = _row("fleet2", model, sizes["windows"], prof, channels,
               metrics, am)
    fleet.close()
    return row


def main(fast: bool = False, sim_only: bool = False):
    sizes = FAST if fast else FULL
    rows = []
    for tag, prioritize in (("baseline", False), ("priority", True)):
        rows.append(run_pilot(tag, sizes, prioritize=prioritize))
        r = rows[-1]
        print(f"  {r['config']:>9}: {r['n_requests']} reqs "
              f"lat_p50={r['lat_p50']:>7.2f}s lat_p99={r['lat_p99']:>7.2f}s "
              f"goodput={r['goodput_tok_s']:>7.1f} tok/s "
              f"preempted={r['n_preempted']} "
              f"peak_bytes={r['peak_channel_bytes']}")
    rows.append(run_fleet2(sizes))
    r = rows[-1]
    print(f"  {r['config']:>9}: {r['n_requests']} reqs "
          f"lat_p50={r['lat_p50']:>7.2f}s lat_p99={r['lat_p99']:>7.2f}s "
          f"goodput={r['goodput_tok_s']:>7.1f} tok/s "
          f"preempted={r['n_preempted']} ttc={r['ttc']}")

    by = {r["config"]: r for r in rows}
    p99_ratio = by["baseline"]["lat_p99"] / max(by["priority"]["lat_p99"],
                                                1e-9)
    goodput_ratio = (by["priority"]["goodput_tok_s"]
                     / max(by["baseline"]["goodput_tok_s"], 1e-9))
    summary = {
        "n_requests": by["priority"]["n_requests"],
        "latency_p99_speedup": round(p99_ratio, 2),
        "goodput_ratio": round(goodput_ratio, 3),
        "n_preempted": by["priority"]["n_preempted"],
        "peak_channel_bytes_max":
            max(r["peak_channel_bytes"] for r in rows),
        "capacity_bytes": CAPACITY_BYTES,
        "fleet2_ttc_ratio": round(
            by["priority"]["ttc"] / max(by["fleet2"]["ttc"], 1e-9), 2)}
    out = {"slots": SLOTS, "model": MODEL_ARGS, "serve": SERVE_ARGS,
           "deadlines": DEADLINES, "fast": fast,
           "rows": [{k: v for k, v in r.items() if k != "serving"}
                    for r in rows],
           "per_class": {r["config"]: r["serving"]["classes"]
                         for r in rows},
           "summary": summary}

    save_results("serve", out["rows"])
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    with open(os.path.join(root, "BENCH_serve.json"), "w") as f:
        json.dump(out, f, indent=1)
    print_csv("serve", out["rows"],
              ["config", "n_requests", "n_tasks", "ttc", "n_preempted",
               "lat_p50", "lat_p99", "goodput_tok_s", "occupancy",
               "peak_channel_bytes"])
    print(f"\nsummary: {json.dumps(summary)}")

    if p99_ratio < 2.0:
        raise SystemExit(
            f"priority scheduling cut latency p99 only {p99_ratio:.2f}x "
            "(bar: 2x) — preemption is not protecting the latency class")
    if goodput_ratio < 0.9:
        raise SystemExit(
            f"priority goodput is {goodput_ratio:.2%} of baseline "
            "(bar: 90%) — preemption is burning throughput")
    if summary["peak_channel_bytes_max"] > CAPACITY_BYTES:
        raise SystemExit(
            f"channel bytes peaked at {summary['peak_channel_bytes_max']} "
            f"over the {CAPACITY_BYTES} budget — back-pressure leaked")
    if by["priority"]["n_preempted"] < 1:
        raise SystemExit("priority row never preempted — the co-tenant "
                         "training bag is not exercising eviction")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="small sizes (CI smoke)")
    ap.add_argument("--sim", action="store_true",
                    help="accepted for CLI parity; all rows are DES")
    a = ap.parse_args()
    main(fast=a.fast, sim_only=a.sim)
