"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def save_results(name: str, rows: List[Dict[str, Any]]):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=1, default=float)


def print_csv(name: str, rows: List[Dict[str, Any]], cols: List[str]):
    print(f"\n# {name}")
    print(",".join(["bench"] + cols))
    for r in rows:
        print(",".join([name] + [f"{r.get(c, '')}" for c in cols]))


class CharCountApp:
    """The paper's two-stage toy workload, instantiable under any pattern."""

    FILE_BYTES = 1 << 18

    @staticmethod
    def mkfile_kernel(instance: int, seed: int = 0):
        from repro.core import Kernel
        k = Kernel("misc.mkfile")
        k.arguments = {"bytes": CharCountApp.FILE_BYTES,
                       "seed": (seed, instance)}
        return k

    @staticmethod
    def ccount_kernel(instance: int):
        from repro.core import Kernel
        return Kernel("misc.ccount")
