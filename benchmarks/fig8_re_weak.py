"""Fig. 8 reproduction: RE weak scaling — replicas = slots, 20..2560.

Expected (paper): simulation phase constant; exchange phase grows with the
replica count (it runs serially over replicas)."""
from __future__ import annotations

from benchmarks.common import print_csv, save_results
from benchmarks.fig7_re_strong import REScaling
from repro.core import SingleClusterEnvironment

SCALES = (20, 40, 80, 160, 320, 640, 1280, 2560)


def run(scales=SCALES, cycles=1) -> list:
    rows = []
    for n in scales:
        cl = SingleClusterEnvironment(resource="local.cpu", cores=n,
                                      walltime=600, mode="sim")
        cl.allocate()
        prof = cl.run(REScaling(cycles=cycles, replicas=n))
        cl.deallocate()
        exch_t = prof.per_stage.get("exchange", {}).get("t_exec", 0.0)
        rows.append({
            "cores": n, "replicas": n,
            "ttc_virtual": round(prof.ttc, 3),
            "sim_phase": round(prof.ttc - exch_t, 3),
            "exchange_phase": round(exch_t, 3),
            "t_rts_overhead_real": round(prof.t_rts_overhead, 4),
            "t_pattern_overhead_real": round(prof.t_pattern_overhead, 4),
            "utilization": round(prof.utilization, 4)})
    return rows


def main(fast: bool = False):
    rows = run((20, 80, 320) if fast else SCALES)
    save_results("fig8_re_weak", rows)
    print_csv("fig8_re_weak", rows,
              ["cores", "replicas", "ttc_virtual", "sim_phase",
               "exchange_phase", "t_rts_overhead_real", "utilization"])
    return rows


if __name__ == "__main__":
    main()
