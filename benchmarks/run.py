"""Benchmark harness: one module per paper exhibit (Figs. 5-10) plus the
beyond-paper fused-dispatch table and the roofline aggregation.

    PYTHONPATH=src python -m benchmarks.run [--full]

Default mode runs reduced scales (a few minutes on this CPU container);
--full runs the paper-scale sweeps (2560 replicas etc.; orchestration is
still real, execution DES-simulated where marked)."""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweeps (slower)")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    args = ap.parse_args()
    fast = not args.full

    from benchmarks import (fig5_patterns, fig6_kernels, fig7_re_strong,
                            fig8_re_weak, fig9_sal_strong, fig10_sal_weak,
                            fused_dispatch, roofline_table)
    benches = {
        "fig5": fig5_patterns.main,
        "fig6": fig6_kernels.main,
        "fig7": fig7_re_strong.main,
        "fig8": fig8_re_weak.main,
        "fig9": fig9_sal_strong.main,
        "fig10": fig10_sal_weak.main,
        "fused": fused_dispatch.main,
        "roofline": roofline_table.main,
    }
    names = args.only.split(",") if args.only else list(benches)
    t0 = time.time()
    failures = []
    for name in names:
        print(f"\n=== {name} " + "=" * 50, flush=True)
        try:
            benches[name](fast=fast)
        except Exception as e:  # keep the harness going
            failures.append((name, repr(e)))
            print(f"BENCH {name} FAILED: {e!r}", file=sys.stderr)
    print(f"\nall benches done in {time.time()-t0:.1f}s; "
          f"{len(failures)} failures")
    for n, e in failures:
        print(f"  FAILED {n}: {e}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
