"""Aggregate dryrun_results/*.json into the §Roofline table."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import print_csv, save_results


def load_rows(results_dir: str = "dryrun_results"):
    rows = []
    for fn in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(fn) as f:
            d = json.load(f)
        rows.append(d)
    return rows


def main(fast: bool = False):
    rows = load_rows()
    ok = [r for r in rows if r.get("status") == "ok"]
    skip = [r for r in rows if r.get("status") == "skipped"]
    err = [r for r in rows if r.get("status") == "error"]
    table = [{
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "t_compute_ms": round(r["t_compute"] * 1e3, 2),
        "t_memory_ms": round(r["t_memory"] * 1e3, 2),
        "t_collective_ms": round(r["t_collective"] * 1e3, 2),
        "bottleneck": r["bottleneck"],
        "useful_ratio": round(r["useful_ratio"], 3),
        "roofline_frac": round(r["roofline_frac"], 4),
    } for r in ok]
    save_results("roofline_table", table)
    print_csv("roofline_table", table,
              ["arch", "shape", "mesh", "t_compute_ms", "t_memory_ms",
               "t_collective_ms", "bottleneck", "useful_ratio",
               "roofline_frac"])
    print(f"\n# {len(ok)} ok, {len(skip)} skipped (documented), "
          f"{len(err)} errors")
    for r in err:
        print(f"#   ERROR {r['arch']} {r['shape']} {r['mesh']}: "
              f"{r.get('error', '')[:100]}")
    return table


if __name__ == "__main__":
    main()
