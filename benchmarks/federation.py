"""Federation benchmark: multi-pilot TTC scaling + recruiter elasticity.

Production ensemble campaigns outgrow one pilot: the EnTK papers scale a
single allocation, real campaigns run several.  This bench drives the
staging bench's O(1000)-task coupled workload (P producer ensembles
streaming cycle payloads into channels consumed by P analysis pipelines)
over fleets of 1, 2 and 4 pilots sharing ONE content-addressed store, in
two regimes:

  static      the fleet starts at its final size; late-binding dispatch
              spreads the stream and keeps consumers next to their
              replicas (``bytes_cross_pilot`` measures what it could not)
  recruiter   the fleet starts at ONE pilot and a backlog-driven
              Recruiter grows it against a slot budget — the TTC gap to
              the same-sized static fleet is the cost of elasticity
              (spin-up latency + hysteresis), and ``direction_flips``
              certifies it converged instead of oscillating

Per row: TTC, dispatch overhead (``t_rts_overhead``), per-pilot dispatch
counts, cross-pilot transfer traffic, recruiter decision log summary.
Emits BENCH_federation.json (repo root) + benchmarks/results/federation
.json.  Fails loudly unless 2 pilots beat 1 by >= 1.8x on the
locality-friendly workload and the recruiter run reports zero direction
flips.  Journals: every pilot writes ``$REPRO_JOURNAL_DIR/federation-*``
when the env var is set (CI sanitizes the captured files).

    PYTHONPATH=src python -m benchmarks.federation [--fast] [--sim]
"""
from __future__ import annotations

import json
import os

from benchmarks.common import print_csv, save_results
from benchmarks.staging import build
from repro.core import AppManager
from repro.federation import Recruiter, build_fleet

PILOT_SLOTS = 8
SLOTS_PER_POD = 2

FULL = dict(pipelines=4, cycles=30, members=8)      # 1080 tasks, width 32
FAST = dict(pipelines=4, cycles=6, members=4)       # 120 tasks, width 16


def _recruiter(max_pilots: int, fast: bool) -> Recruiter:
    return Recruiter(
        min_pilots=1, max_pilots=max_pilots,
        slots_per_pilot=PILOT_SLOTS,
        budget_slots=max_pilots * PILOT_SLOTS,
        hysteresis_s=2.0 if fast else 8.0,
        spinup_s=1.0 if fast else 5.0,
        grow_backlog_factor=1.5)


def run_fleet(n_pilots: int, mode: str, sizes: dict, *,
              recruit: bool = False, fast: bool = False) -> dict:
    tag = f"{n_pilots}p{'-recruiter' if recruit else ''}-{mode}"
    fleet = build_fleet(
        1 if recruit else n_pilots, slots=PILOT_SLOTS, mode=mode,
        slots_per_pod=SLOTS_PER_POD, threshold_bytes=1024,
        journal_base=f"federation-{tag}",
        recruiter=_recruiter(n_pilots, fast) if recruit else None)
    am = AppManager(fleet)
    payload_floats = 4096 if mode == "real" else 0
    prof = am.run(build(mode, **sizes, payload_floats=payload_floats))
    if prof.n_failed:
        raise SystemExit(f"{tag}: {prof.n_failed} failed tasks")

    fed = prof.results["federation"]
    tr = fleet.staging.planner.summary()
    row = {"config": tag, "mode": mode, "n_pilots_final": fed["n_active"],
           "recruiter": recruit, "n_tasks": prof.n_tasks,
           "ttc": round(prof.ttc, 3),
           "t_rts_overhead": round(prof.t_rts_overhead, 4),
           "t_data_total": round(prof.t_data, 4),
           "dispatch": fed["dispatch"],
           "locality_hit_rate": tr["locality_hit_rate"],
           "cross_pilot": tr["cross_pilot"],
           "bytes_cross_pilot": tr["bytes_cross_pilot"]}
    if recruit:
        row["recruiter_summary"] = fed["recruiter"]
    fleet.close()
    return row


def main(fast: bool = False, sim_only: bool = False):
    sizes = FAST if fast else FULL
    rows = []
    for n in (1, 2, 4):
        rows.append(run_fleet(n, "sim", sizes, fast=fast))
        r = rows[-1]
        print(f"  {r['config']:>18}: ttc={r['ttc']:>8.1f}s "
              f"overhead={r['t_rts_overhead']:.3f}s "
              f"cross_pilot={r['cross_pilot']}")
    rows.append(run_fleet(4, "sim", sizes, recruit=True, fast=fast))
    r = rows[-1]
    print(f"  {r['config']:>18}: ttc={r['ttc']:>8.1f}s "
          f"recruiter={json.dumps(r['recruiter_summary'])}")
    if not sim_only:
        rows.append(run_fleet(2, "real", FAST, fast=True))
        r = rows[-1]
        print(f"  {r['config']:>18}: ttc={r['ttc']:>8.3f}s "
              f"dispatch={json.dumps(r['dispatch'])}")

    by = {r["config"]: r for r in rows}
    speedup_2 = by["1p-sim"]["ttc"] / max(by["2p-sim"]["ttc"], 1e-9)
    speedup_4 = by["1p-sim"]["ttc"] / max(by["4p-sim"]["ttc"], 1e-9)
    rec = by["4p-recruiter-sim"]
    summary = {
        "speedup_2_pilots": round(speedup_2, 3),
        "speedup_4_pilots": round(speedup_4, 3),
        "elasticity_cost_s": round(rec["ttc"] - by["4p-sim"]["ttc"], 3),
        "recruiter_direction_flips":
            rec["recruiter_summary"]["direction_flips"],
        "bytes_cross_pilot_max":
            max(r["bytes_cross_pilot"] for r in rows)}
    out = {"pilot_slots": PILOT_SLOTS, "slots_per_pod": SLOTS_PER_POD,
           "rows": rows, "summary": summary}

    save_results("federation", rows)
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    with open(os.path.join(root, "BENCH_federation.json"), "w") as f:
        json.dump(out, f, indent=1)
    print_csv("federation", rows,
              ["config", "mode", "n_pilots_final", "n_tasks", "ttc",
               "t_rts_overhead", "cross_pilot", "bytes_cross_pilot"])
    print(f"\nsummary: {json.dumps(summary)}")

    if speedup_2 < 1.8:
        raise SystemExit(
            f"2-pilot speedup {speedup_2:.2f} below the 1.8x bar — "
            "late-binding dispatch is not spreading the stream")
    if summary["recruiter_direction_flips"] > 0:
        raise SystemExit(
            f"recruiter oscillated ({summary['recruiter_direction_flips']}"
            " direction flips) — hysteresis is not holding")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="small sizes (CI smoke)")
    ap.add_argument("--sim", action="store_true",
                    help="DES rows only (no real-mode run)")
    a = ap.parse_args()
    main(fast=a.fast, sim_only=a.sim)
