"""Fig. 7 reproduction: RE strong scaling — 2560 replicas, 20..2560 slots.

Execution is DES-simulated (calibrated per-replica duration; the paper's
6 ps Amber segment ~ 100 s on one core); scheduler/bookkeeping overheads are
measured on the real clock.  Expected: simulation phase time halves per slot
doubling; exchange time constant (depends only on the fixed replica count).
"""
from __future__ import annotations

from benchmarks.common import print_csv, save_results
from repro.core import Kernel, ReplicaExchange, SingleClusterEnvironment

REPLICAS = 2560
SLOTS = (20, 40, 80, 160, 320, 640, 1280, 2560)
SIM_SECONDS = 100.0          # calibrated per-replica MD segment
EXCH_PER_REPLICA = 0.005     # serial exchange cost per replica


class REScaling(ReplicaExchange):
    def prepare_replica_for_md(self, r):
        k = Kernel("synthetic.noop")
        k.sim_duration = SIM_SECONDS
        return k

    def prepare_exchange(self, replicas):
        k = Kernel("synthetic.noop")
        k.sim_duration = EXCH_PER_REPLICA * len(replicas)
        return k


def run(slots=SLOTS, replicas=REPLICAS, cycles=1) -> list:
    rows = []
    for n in slots:
        cl = SingleClusterEnvironment(resource="local.cpu", cores=n,
                                      walltime=600, mode="sim")
        cl.allocate()
        prof = cl.run(REScaling(cycles=cycles, replicas=replicas))
        cl.deallocate()
        sim_t = prof.per_stage.get("simulation", {}).get("t_exec", 0.0)
        exch_t = prof.per_stage.get("exchange", {}).get("t_exec", 0.0)
        rows.append({
            "cores": n, "replicas": replicas,
            "ttc_virtual": round(prof.ttc, 3),
            "sim_phase": round(prof.ttc - exch_t, 3),
            "exchange_phase": round(exch_t, 3),
            "sim_total_slotsec": round(sim_t, 1),
            "t_rts_overhead_real": round(prof.t_rts_overhead, 4),
            "t_pattern_overhead_real": round(prof.t_pattern_overhead, 4),
            "utilization": round(prof.utilization, 4)})
    return rows


def main(fast: bool = False):
    rows = run(slots=(20, 80, 320) if fast else SLOTS,
               replicas=320 if fast else REPLICAS)
    save_results("fig7_re_strong", rows)
    print_csv("fig7_re_strong", rows,
              ["cores", "replicas", "ttc_virtual", "sim_phase",
               "exchange_phase", "t_rts_overhead_real",
               "t_pattern_overhead_real", "utilization"])
    return rows


if __name__ == "__main__":
    main()
